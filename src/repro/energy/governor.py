"""ParetoGovernor: continuous operating-point control on the DP frontier.

Replaces the ``LoadWatermarkPolicy``'s binary perf/energy flip with a
*monotone frontier walk*: each tick, the governor reads the
``ArrivalForecaster``'s per-signature demand and pins every signature to
the **lowest-energy** operating point whose aggregate throughput
(point throughput x serving replicas) still clears the forecast demand
plus a headroom factor. Upshifts (toward the perf endpoint) apply
immediately — never serve a rush under-provisioned; downshifts are gated
by a hysteresis band (the cheaper point must clear demand with *extra*
margin) so the frontier walk cannot flap between adjacent rungs on
forecast noise.

On top of demand tracking sits the fleet ``PowerBudget``: when the
modeled fleet draw exceeds the cap in force, the governor force-downshifts
the **coldest** cells first (lowest smoothed arrival rate, ties broken on
the signature itself) one rung at a time until the fleet fits — hot cells
keep their throughput for as long as the budget allows.

Operating-point changes flow through ``DynamicScheduler.set_target``:
an epoch bump invalidates resident pipeline handles, and the next submit
re-prepares the cell under the new point via the standard per-host DP
re-solve — exactly the path pool resizes and mode flips already take.
Every decision is appended as a derived ``opoint`` event, and every tick
as a ``power`` sample, to the cluster event log, so a recorded capped run
replays byte-identically (all inputs — forecast state, frontier, budget —
are deterministic functions of the arrival stream and the script).

Units: watts and joules per ``core.energy_model``; time is the simulated
clock. The energy SLO is J/request (== J/inference at the serving batch
granularity).
"""
from __future__ import annotations

import zlib

from ..cluster.events import ClusterEvent, ClusterEventLog
from ..core.dynamic import signature
from .frontier import FrontierCache

_LOCAL_WID = "local"


def sig_tag(sig) -> str:
    """A short, deterministic display tag for a workload signature (the
    dashboard's per-cell operating-point label): leading kernel kind plus
    a CRC of the full signature."""
    kind = sig[0][0] if sig else "-"
    return f"{kind}#{zlib.crc32(repr(sig).encode()) & 0xffff:04x}"


class ParetoGovernor:
    def __init__(self, *, interval: float = 1.0, headroom: float = 1.1,
                 hysteresis: float = 0.25, budget=None,
                 energy_slo_j: float | None = None):
        assert interval > 0 and headroom >= 1.0 and hysteresis >= 0.0
        self.interval = interval       # decision cadence (sim seconds)
        self.headroom = headroom       # capacity must clear demand x this
        self.hysteresis = hysteresis   # extra margin required to downshift
        self.budget = budget           # PowerBudget | None
        self.energy_slo_j = energy_slo_j
        self.router = None
        self.ctrl = None
        self.forecaster = None
        self.frontiers: FrontierCache | None = None
        self.events = ClusterEventLog()   # local-mode event sink
        self._idx: dict = {}           # sig -> current frontier index
        self._last_tick = -float("inf")
        self._pool = None              # full pool counts, resize detection
        # dashboard-facing state (last completed tick)
        self.last_watts = 0.0
        self.last_cap: float | None = None
        self.last_downshifts = 0
        self.points: dict = {}         # sig -> current OperatingPoint

    # -- wiring ----------------------------------------------------------------
    def attach(self, router, controller=None):
        """Wire into a serving Router (and optionally a cluster
        Controller) as a clock hook. Setting ``router.governor`` stands
        the watermark policy's mode flip down; arrivals keep feeding the
        policy's forecaster, which the governor reads."""
        fc = getattr(router.policy, "forecaster", None)
        if fc is None:
            raise ValueError("ParetoGovernor needs a policy with an "
                             "ArrivalForecaster (serve: --forecast-horizon)")
        self.router = router
        self.ctrl = controller
        self.forecaster = fc
        self.frontiers = FrontierCache(router.dyn)
        router.governor = self
        router.clock_hooks.append(self.tick)
        if controller is not None and self.budget is not None:
            controller.power_budget = self.budget
        return self

    def _log(self):
        return self.ctrl.events if self.ctrl is not None else self.events

    # -- per-signature capacity model ------------------------------------------
    def _replica_hosts(self) -> dict:
        """sig -> worker ids serving its cell (latest-epoch cell wins);
        local mode serves everything on the one in-process 'worker'."""
        if self.ctrl is None:
            return {}
        best: dict = {}
        for hid, (_s, wl, ep) in self.ctrl._cells.items():
            sig = signature(wl)
            if sig not in best or (ep, hid) > best[sig]:
                best[sig] = (ep, hid)
        out = {}
        for sig, (_ep, hid) in best.items():
            hosts = self.ctrl.replica_hosts(hid)
            if hosts:
                out[sig] = hosts
        return out

    # -- frontier selection ----------------------------------------------------
    def _allowed(self, front):
        """The SLO-feasible slice of a frontier (energy per inference at
        or under the SLO); empty-safe — when even the energy endpoint
        violates the SLO, that endpoint is the least-bad choice."""
        if self.energy_slo_j is None:
            return front, False
        ok = tuple(p for p in front if p.energy <= self.energy_slo_j)
        if not ok:
            return (front[-1],), True
        return ok, len(ok) != len(front)

    @staticmethod
    def _cheapest_clearing(points, need: float, replicas: int):
        """Lowest-energy point whose aggregate throughput clears
        ``need``; the fastest available point when none does."""
        for p in reversed(points):     # cheapest (highest idx) first
            if p.throughput * replicas >= need - 1e-12:
                return p
        return points[0]

    def _desired(self, front, demand: float, replicas: int, cur):
        """The hysteresis-banded target point: immediate upshift, gated
        downshift. Returns (point, reason)."""
        allowed, slo_bound = self._allowed(front)
        need = demand * self.headroom
        want = self._cheapest_clearing(allowed, need, replicas)
        reason = ("slo" if slo_bound and want.idx
                  != self._cheapest_clearing(front, need, replicas).idx
                  else "demand")
        if cur is None or want.idx < cur:
            return want, reason        # upshift / first sighting: take it
        if want.idx > cur:
            # downshift only with hysteresis margin to spare
            strict = self._cheapest_clearing(
                allowed, need * (1.0 + self.hysteresis), replicas)
            if strict.idx > cur:
                return strict, reason
        return None, reason            # hold the current rung

    # -- the decision tick -----------------------------------------------------
    def tick(self, now: float):
        if now - self._last_tick < self.interval - 1e-9:
            return None
        self._last_tick = now
        fc = self.forecaster
        if not fc.warmed_up:
            return None
        dyn = self.router.dyn
        pool = tuple(cnt for _, cnt in dyn.system.pools)
        if pool != self._pool:         # elastic resize: fronts are stale
            self.frontiers.invalidate()
            self._idx.clear()
            self.points.clear()
            self._pool = pool
        replica_hosts = self._replica_hosts()
        # frontiers live on the Engine's fair-share sub-pool — the pool
        # admission actually schedules cells on — so the frac knob and the
        # running schedules agree on the same throughput denominator
        share = self.router.engine._share_cap()

        tracked = []                   # (sig, front, replicas, hosts)
        for sig, wl in fc.signatures():
            try:
                front = self.frontiers.frontier(wl, pool=share)
            except RuntimeError:
                # infeasible under the share cap: admission would fall
                # back to the full pool, so the frontier does too
                try:
                    front = self.frontiers.frontier(wl)
                except RuntimeError:
                    front = ()
            if not front:
                continue
            hosts = replica_hosts.get(sig, (_LOCAL_WID,))
            tracked.append((sig, front, hosts))

        # 1) demand pass: per-signature hysteresis-banded frontier walk —
        #    PLANNED only; nothing is applied until the budget pass has
        #    had its say, so a demand upshift the cap would immediately
        #    claw back never costs an epoch bump
        plan: dict = {}                # sig -> [planned idx, reason]
        for sig, front, hosts in tracked:
            demand = fc.sig_forecast(now, sig)
            cur = self._idx.get(sig)
            pt, reason = self._desired(front, demand, len(hosts), cur)
            plan[sig] = [pt.idx if pt is not None else cur, reason]

        # 2) budget pass: while the planned assignment busts the cap,
        #    claw the *coldest* signature (lowest smoothed rate, ties on
        #    the signature) down one rung at a time
        cap = self.budget.cap(now) if self.budget is not None else None
        downshifts = 0
        worker_watts = self._worker_watts(tracked, plan)
        if cap is not None:
            while sum(worker_watts.values()) > cap + 1e-9:
                cold = None
                for sig, front, _hosts in tracked:
                    if plan[sig][0] >= len(front) - 1:
                        continue       # already at the energy endpoint
                    key = (fc.sig_rate(sig), sig)
                    if cold is None or key < cold[0]:
                        cold = (key, sig, front)
                if cold is None:
                    break              # nothing left to shed
                _key, sig, front = cold
                plan[sig] = [plan[sig][0] + 1, "cap"]
                downshifts += 1
                worker_watts = self._worker_watts(tracked, plan)

        # 3) apply the diff: one set_target (epoch bump) per signature
        #    whose final rung moved
        for sig, front, _hosts in tracked:
            idx, reason = plan[sig]
            if idx != self._idx.get(sig):
                self._apply(now, sig, front[idx], reason)

        # 4) publish: power sample, budget headroom, dashboard state
        fleet = round(sum(worker_watts.values()), 9)
        self.last_watts, self.last_cap = fleet, cap
        self.last_downshifts = downshifts
        self._log().append(ClusterEvent(now, "power", "", {
            "watts": fleet, "cap": cap, "downshifts": downshifts}))
        self.router.metrics.record_power(now, fleet)
        if self.budget is not None:
            n_active = (len(self.ctrl.active_workers())
                        if self.ctrl is not None else 1)
            self.budget.note(worker_watts, n_active)
        if self.router.tracer.enabled:
            self.router.tracer.instant("governor", "power", now,
                                       watts=fleet, cap=cap,
                                       downshifts=downshifts)
        return None

    def _worker_watts(self, tracked, plan) -> dict:
        """Modeled per-worker draw under a planned assignment: each
        serving replica of a signature's cell runs at the signature's
        planned operating point (its rating — energy x throughput)."""
        out: dict = {}
        for sig, front, hosts in tracked:
            pt = front[plan[sig][0]]
            for wid in hosts:
                out[wid] = out.get(wid, 0.0) + pt.watts
        return out

    def _apply(self, now: float, sig, pt, reason: str) -> None:
        """Move one signature to frontier point ``pt``: pin the target
        (epoch bump -> handle invalidation -> lazy DP re-prepare) and
        record the derived event."""
        self.router.dyn.set_target(sig, pt.frac)
        self._idx[sig] = pt.idx
        self.points[sig] = pt
        self._log().append(ClusterEvent(now, "opoint", "", {
            "sig": str(sig), "idx": pt.idx, "frac": pt.frac,
            "watts": round(pt.watts, 9), "reason": reason}))
        if self.router.tracer.enabled:
            self.router.tracer.instant("governor", "opoint", now,
                                       sig=sig_tag(sig), idx=pt.idx,
                                       frac=pt.frac, reason=reason)
