"""Fleet power budget: a watts cap the ``ParetoGovernor`` enforces and
the cluster ``Controller`` consults for placement headroom.

Units follow ``core.energy_model``: watts throughout, simulated-clock
seconds for schedule times. The cap is a step function of simulated time
(``cap_schedule`` overrides — the chaos/property tests randomize these),
partitioned *equally* across the controller's active workers: a worker's
share is ``cap / n_active``, and placement prefers workers still under
their share. All watts here are *modeled* (operating-point energy x
throughput), never measured hardware — that is what makes every budget
decision a derived, byte-identically replayable event.
"""
from __future__ import annotations


class PowerBudget:
    """A fleet-wide cap in watts, with optional scheduled re-caps.

    ``cap_schedule`` is an iterable of ``(t, cap_w)`` pairs: from
    simulated time ``t`` onward the cap is ``cap_w`` (step function;
    the base ``cap_w`` applies before the first step). The governor
    publishes per-worker draw via ``note`` each tick; ``Controller.place``
    and ``Controller._best_host`` read ``worker_headroom`` to steer new
    cells and replicas toward workers with watts to spare.
    """

    def __init__(self, cap_w: float, *, cap_schedule=()):
        self.base_cap = float(cap_w)
        self.cap_schedule = tuple(sorted(
            (float(t), float(c)) for t, c in cap_schedule))
        #: wid -> modeled watts, published by the governor after each
        #: tick's enforcement pass (empty until the first tick)
        self.worker_watts: dict[str, float] = {}
        self._n_workers = 1

    def cap(self, now: float) -> float:
        """The cap in force at simulated time ``now``."""
        cap = self.base_cap
        for t, c in self.cap_schedule:
            if now + 1e-12 < t:
                break
            cap = c
        return cap

    def note(self, watts_by_worker: dict, n_workers: int | None = None):
        """Governor tick: publish the post-enforcement per-worker draw
        (and the active-worker count the equal partition divides by)."""
        self.worker_watts = dict(watts_by_worker)
        if n_workers:
            self._n_workers = n_workers

    def fleet_watts(self) -> float:
        return sum(self.worker_watts.values())

    def share(self, now: float) -> float:
        """One worker's equal slice of the fleet cap."""
        return self.cap(now) / max(1, self._n_workers)

    def headroom(self, now: float) -> float:
        """Fleet-level watts to spare (negative = over cap)."""
        return self.cap(now) - self.fleet_watts()

    def worker_headroom(self, now: float, wid: str) -> float:
        """Watts worker ``wid`` has left under its equal share."""
        return self.share(now) - self.worker_watts.get(wid, 0.0)

    def over(self, now: float) -> bool:
        return self.fleet_watts() > self.cap(now) + 1e-9
