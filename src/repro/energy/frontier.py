"""Operating-point frontier: ``Scheduler.pareto()`` materialized into the
discrete, ordered menu the ``ParetoGovernor`` walks at runtime.

The DP scheduler exposes a *strictly* monotone Pareto front per workload
signature (descending throughput, descending energy/inference — see
``Scheduler.pareto``). This module turns each front entry into an
``OperatingPoint`` with the derived quantities the governor trades on:

  * ``watts``  — steady-state power of one serving replica at that point,
    ``energy [J/inf] x throughput [inf/s]`` (see
    ``core.energy_model.pipeline_power`` for the unit conventions);
  * ``frac``   — the point's throughput as a *floor*-quantized fraction of
    the front's maximum. Feeding ``frac`` to
    ``DynamicScheduler.set_target`` makes the balanced-mode DP selection
    (min energy subject to ``throughput >= frac x max``) re-derive exactly
    this point, so the governor's choice and the scheduler's cache agree
    on one schedule. Floor (not round) quantization keeps the chosen
    point itself feasible at its own fraction.

Index 0 is always the perf endpoint (``frac == 1.0``); the last index is
the energy endpoint. Downshifting = moving to a higher index.

Fronts are cached per ``(signature, pool, host-profile)`` — the same cell
key the DynamicScheduler caches schedules under — so a steady fleet pays
the endpoint enumeration once per cell, not once per tick.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.dynamic import signature

#: frac quantization grid (must match DynamicScheduler.set_target's
#: round(..., 3) so floor-quantized values survive the round-trip)
FRAC_GRID = 1000


def quantize_frac(ratio: float) -> float:
    """Floor-quantize a throughput ratio onto the grid ``set_target``
    rounds to. Floor, not round: rounding up could demand more throughput
    than the point itself delivers, bouncing the balanced-mode selection
    to a faster, hungrier point."""
    return max(1.0 / FRAC_GRID, math.floor(ratio * FRAC_GRID) / FRAC_GRID)


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One rung of a signature's Pareto frontier (per serving replica)."""
    idx: int              # 0 = perf endpoint; increasing = cheaper/slower
    frac: float           # set_target knob reproducing this point
    throughput: float     # inferences / s
    energy: float         # J / inference
    watts: float          # energy x throughput (steady-state draw)
    devices: int          # devices the pipeline occupies
    mnemonic: str

    def dominates(self, other: "OperatingPoint") -> bool:
        return (self.throughput >= other.throughput
                and self.energy <= other.energy
                and (self.throughput > other.throughput
                     or self.energy < other.energy))


def materialize(scheduler, wl) -> tuple:
    """The workload's frontier as an ordered ``OperatingPoint`` tuple
    (index 0 = perf endpoint). Empty when the workload has no feasible
    pipeline on the scheduler's pool."""
    front = scheduler.pareto(wl)
    if not front:
        return ()
    max_thp = front[0]["throughput"]
    pts = []
    for i, d in enumerate(front):
        thp, e = d["throughput"], d["energy"]
        frac = 1.0 if i == 0 else quantize_frac(thp / max_thp)
        pts.append(OperatingPoint(
            idx=i, frac=frac, throughput=thp, energy=max(0.0, e),
            watts=max(0.0, e) * thp, devices=d["devices"],
            mnemonic=d["mnemonic"]))
    return tuple(pts)


class FrontierCache:
    """Per-(signature, pool, host) memo of materialized frontiers, built
    lazily from a ``DynamicScheduler``'s underlying DP scheduler. The
    host key is the ``HostProfile`` (hashable dataclass) or None, exactly
    mirroring the DynamicScheduler's schedule-cache cell key."""

    def __init__(self, dyn):
        self.dyn = dyn
        self._fronts: dict = {}

    def invalidate(self) -> None:
        """Pool resize / profile relearn: every cached front is stale."""
        self._fronts.clear()

    def frontier(self, wl, pool=None, host=None) -> tuple:
        pool = self.dyn._norm_pool(pool)
        host = None if (host is None or host.is_uniform) else host
        key = (signature(wl), pool, host)
        front = self._fronts.get(key)
        if front is None:
            sched = self.dyn._scheduler_for(pool, host)
            front = materialize(sched, wl)
            self._fronts[key] = front
        return front
