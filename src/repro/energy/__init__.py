"""repro.energy: continuous Pareto-frontier serving.

``frontier`` materializes the DP scheduler's Pareto front into ordered
operating points; ``governor`` walks that frontier against the arrival
forecast each control tick; ``budget`` caps the fleet's modeled power
draw and steers placement by watts headroom. See ``docs/energy.md``.
"""
from .budget import PowerBudget
from .frontier import FrontierCache, OperatingPoint, materialize, quantize_frac
from .governor import ParetoGovernor

__all__ = [
    "FrontierCache",
    "OperatingPoint",
    "ParetoGovernor",
    "PowerBudget",
    "materialize",
    "quantize_frac",
]
