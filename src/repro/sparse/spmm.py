"""SpMM entry points over the CSR substrate.

``spmm_csr`` is the pure-JAX gather/segment-sum path used by the GNN models
on CPU and as the oracle; the Pallas blocked-ELL kernel (kernels/spmm.py) is
the TPU hot path for large graphs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .formats import CSR


def spmm_csr(a: CSR, x: jnp.ndarray) -> jnp.ndarray:
    """Â @ X via gather + segment_sum. x: (K, N) -> (M, N)."""
    M = a.shape[0]
    rows = jnp.repeat(jnp.arange(M, dtype=jnp.int32),
                      jnp.diff(a.indptr), total_repeat_length=a.nnz)
    gathered = x[a.indices] * a.data[:, None]
    return jax.ops.segment_sum(gathered, rows, num_segments=M)


def spmm_dense_ref(a_dense, x):
    return jnp.asarray(a_dense) @ jnp.asarray(x)
