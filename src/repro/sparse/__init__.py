"""Sparse substrate: CSR / blocked-ELL formats and SpMM entry points."""
from .formats import CSR, csr_from_dense, csr_to_dense, random_graph_csr
from .spmm import spmm_csr, spmm_dense_ref
