"""Sparse matrix formats.

CSR is the exchange format (matches the paper's Sextans input); the Pallas
kernel consumes blocked-ELL (see kernels/spmm.py). ``random_graph_csr``
generates Table-I-like synthetic graphs (uniform edges + self loops,
degree-normalized values — the GCN Â matrix).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class CSR:
    """Row-compressed sparse matrix (device arrays)."""
    indptr: jnp.ndarray    # (M+1,) int32
    indices: jnp.ndarray   # (nnz,) int32
    data: jnp.ndarray      # (nnz,) float
    shape: tuple

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def sparsity(self) -> float:
        return 1.0 - self.nnz / (self.shape[0] * self.shape[1])


def csr_from_dense(a: np.ndarray) -> CSR:
    M, K = a.shape
    indptr = [0]
    indices, data = [], []
    for r in range(M):
        cols = np.nonzero(a[r])[0]
        indices.extend(cols.tolist())
        data.extend(a[r, cols].tolist())
        indptr.append(len(indices))
    return CSR(jnp.asarray(indptr, jnp.int32),
               jnp.asarray(indices, jnp.int32),
               jnp.asarray(np.asarray(data, a.dtype)), (M, K))


def csr_to_dense(a: CSR) -> np.ndarray:
    M, K = a.shape
    out = np.zeros((M, K), np.float32)
    indptr = np.asarray(a.indptr)
    idx = np.asarray(a.indices)
    dat = np.asarray(a.data)
    for r in range(M):
        out[r, idx[indptr[r]:indptr[r + 1]]] = dat[indptr[r]:indptr[r + 1]]
    return out


def random_graph_csr(n_vertices: int, n_edges: int, *, seed: int = 0,
                     normalized: bool = True) -> CSR:
    """Synthetic graph adjacency (+ self loops), GCN-normalized:
    Â = D^-1/2 (I + A) D^-1/2. Returns CSR of Â."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_vertices, n_edges)
    dst = rng.integers(0, n_vertices, n_edges)
    # + self loops, dedup
    src = np.concatenate([src, np.arange(n_vertices)])
    dst = np.concatenate([dst, np.arange(n_vertices)])
    key = src.astype(np.int64) * n_vertices + dst
    key = np.unique(key)
    src, dst = (key // n_vertices).astype(np.int32), (key % n_vertices).astype(np.int32)
    deg = np.bincount(src, minlength=n_vertices).astype(np.float32)
    if normalized:
        dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
        val = dinv[src] * dinv[dst]
    else:
        val = np.ones_like(src, np.float32)
    order = np.lexsort((dst, src))
    src, dst, val = src[order], dst[order], val[order]
    indptr = np.zeros(n_vertices + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(jnp.asarray(indptr, jnp.int32), jnp.asarray(dst, jnp.int32),
               jnp.asarray(val.astype(np.float32)), (n_vertices, n_vertices))
