"""OnlineHostEstimator: learn each host's ``HostProfile`` from the
measured stream instead of being told (``--host-profiles``).

DyPe's core claim is that schedules should be *discovered from
measurement*, not configured by hand — yet the cluster layer still makes
operators declare per-host scales while every ``CompletionReport``
already carries the signal: the worker measured its stage times against
physical truth, and ``stage_expected`` records what the controller's
belief predicted for the same stages. The measured/expected gap per
stage is a linear function of exactly the unknowns a ``HostProfile``
holds:

    measured[s] = exec_expected[s] * r_dev(s)  +  xfer_expected[s] * u

where ``r_dev`` is the host's execution-time ratio (truth/belief) for
the stage's device type and ``u`` the transfer-time ratio
(belief_bw / truth_bw). The estimator accumulates per-worker stage
observations and solves the ridge least-squares system over
(one ratio per device type seen, plus ``u``), with the prior pulling
every unknown toward 1.0 — the "this host matches my belief" null
hypothesis. Confidence comes from the usual stderr of the LS solution:
a profile is *published* only once every evidenced unknown has at least
``min_obs`` observations and a relative stderr at or under ``rel_tol``,
AND the estimate deviates from belief beyond ``dead_band`` — a healthy
fleet (ratios pinned at 1.0) never publishes anything.

Publication composes the learned ratios over the current belief (so the
loop also tracks *drift* of an already-declared or already-learned
profile) and resets the worker's observations: the next reports are
judged against the new belief, whose ratios should sit at 1.0.

The second job is **straggler gating**: while a host-level mismatch is
in flight (a 60x measured/expected ratio on every stage), feeding those
measurements to the per-stage ``StragglerMonitor``s would demote every
device on the host — the wrong diagnosis at the wrong granularity.
``observe_report`` returns True for a mismatched report; the Router
withholds exactly those from the monitors until the learned profile
lands (after which ratios return to ~1.0 and per-stage straggler
detection resumes, now against host-correct baselines).

Everything here is a deterministic function of the report stream, so
learned-profile publications are *derived* cluster events: replaying a
recorded run re-derives byte-identical decisions.

Plain single-threaded state driven by the host control loop, like the
monitors it gates.
"""
from __future__ import annotations

import collections
import dataclasses
import math

from ..core.device import UNIFORM_HOST, HostProfile

#: Solver key for the shared transfer-ratio unknown (not a device name).
_BW = "~bw"


def _gauss(m: list, b: list) -> list:
    """Solve ``m x = b`` in place (partial pivoting); tiny k (<= #device
    types + 1), so no numerics library needed."""
    k = len(b)
    for col in range(k):
        piv = max(range(col, k), key=lambda r: abs(m[r][col]))
        if abs(m[piv][col]) < 1e-30:
            continue                     # ridge keeps this unreachable
        m[col], m[piv] = m[piv], m[col]
        b[col], b[piv] = b[piv], b[col]
        inv = 1.0 / m[col][col]
        for r in range(k):
            if r == col:
                continue
            f = m[r][col] * inv
            if f == 0.0:
                continue
            for c in range(col, k):
                m[r][c] -= f * m[col][c]
            b[r] -= f * b[col]
    return [b[i] / m[i][i] if abs(m[i][i]) > 1e-30 else 1.0
            for i in range(k)]


@dataclasses.dataclass
class HostEstimate:
    """One worker's solved state: ``ratios[dev]`` is the estimated
    truth/belief execution-time ratio per device type, ``bw_ratio`` the
    transfer-time ratio (belief_bw / truth_bw; 1.0 and ``bw_evidence``
    False when no stage carried transfer time). ``rel_err`` is the max
    relative stderr over evidenced unknowns — the confidence bound the
    publish gate checks."""
    wid: str
    ratios: dict
    bw_ratio: float
    bw_evidence: bool
    n: int
    rel_err: float
    converged: bool


class OnlineHostEstimator:
    def __init__(self, *, min_obs: int = 4, rel_tol: float = 0.15,
                 dead_band: float = 0.10, ridge: float = 1e-6,
                 max_obs: int = 512):
        self.min_obs = min_obs
        self.rel_tol = rel_tol
        self.dead_band = dead_band
        self.ridge = ridge
        self.max_obs = max_obs
        # wid -> deque of (dev, exec_expected, xfer_expected, measured)
        self._obs: dict[str, collections.deque] = {}
        self._dirty: set[str] = set()
        self._cache: dict[str, HostEstimate] = {}
        self.beliefs: dict[str, HostProfile] = {}
        self.published: dict[str, HostProfile] = {}
        self.gated = 0                 # reports withheld from the monitors

    # -- ingest ----------------------------------------------------------------
    def observe_report(self, report) -> bool:
        """Feed one ``CompletionReport`` (sim-clock measurements); returns
        True when the report is *mismatched* against its belief
        expectations — the caller should withhold it from the straggler
        monitors, because host-level slowness explains the drift."""
        wid = getattr(report, "worker", "")
        expected = getattr(report, "stage_expected", ())
        if not wid or not expected:
            return False
        return self._ingest(wid, [(d, e, x, m) for (d, e, x), m
                                  in zip(expected, report.measured)])

    def observe_stages(self, wid: str, stage_devs, expected,
                       measured) -> bool:
        """Feed calibrated per-stage times with total-seconds expectations
        (the ``WallClockCalibrator`` path — no exec/transfer split, so the
        whole stage is attributed to execution). Same return contract as
        ``observe_report``."""
        if not wid:
            return False
        return self._ingest(wid, [(d, e, 0.0, m) for d, e, m
                                  in zip(stage_devs, expected, measured)])

    def _ingest(self, wid: str, rows) -> bool:
        q = self._obs.setdefault(
            wid, collections.deque(maxlen=self.max_obs))
        mismatch = False
        for dev, e, x, m in rows:
            tot = e + x
            if tot <= 1e-12:
                continue               # degenerate stage: no signal
            q.append((dev, float(e), float(x), float(m)))
            ratio = m / tot
            if not (1.0 / (1.0 + self.dead_band) <= ratio
                    <= 1.0 + self.dead_band):
                mismatch = True
        if rows:
            self._dirty.add(wid)
        if mismatch:
            self.gated += 1
        return mismatch

    # -- solve -----------------------------------------------------------------
    def estimate(self, wid: str) -> HostEstimate | None:
        """Current least-squares solution for one worker (cached until new
        observations arrive); None before any usable observation."""
        if wid not in self._dirty and wid in self._cache:
            return self._cache[wid]
        obs = self._obs.get(wid)
        if not obs:
            return None
        est = self._solve(wid, list(obs))
        self._cache[wid] = est
        self._dirty.discard(wid)
        return est

    def _solve(self, wid: str, obs: list) -> HostEstimate:
        devs = sorted({d for d, _, _, _ in obs})
        unknowns = devs + [_BW]
        k = len(unknowns)
        idx = {u: i for i, u in enumerate(unknowns)}
        m = [[0.0] * k for _ in range(k)]
        b = [0.0] * k
        counts = {u: 0 for u in unknowns}
        for dev, e, x, y in obs:
            i, j = idx[dev], k - 1
            m[i][i] += e * e
            m[i][j] += e * x
            m[j][i] += e * x
            m[j][j] += x * x
            b[i] += e * y
            b[j] += x * y
            counts[dev] += 1
            if x > 1e-12:
                counts[_BW] += 1
        # ridge prior toward 1.0 ("host matches belief"), scaled to the
        # normal matrix so it regularizes without biasing strong evidence;
        # it also pins unevidenced unknowns (no transfer stages) at 1.0
        lam = self.ridge * max(max(m[i][i] for i in range(k)), 1e-12)
        for i in range(k):
            m[i][i] += lam
            b[i] += lam
        theta = _gauss([row[:] for row in m], b[:])
        sse = 0.0
        for dev, e, x, y in obs:
            pred = e * theta[idx[dev]] + x * theta[k - 1]
            sse += (y - pred) ** 2
        sigma2 = sse / max(len(obs) - k, 1)
        errs = {}
        for u, i in idx.items():
            ei = [0.0] * k
            ei[i] = 1.0
            z = _gauss([row[:] for row in m], ei)
            errs[u] = math.sqrt(max(sigma2 * z[i], 0.0))
        evidenced = [u for u in unknowns if counts[u] >= self.min_obs]
        rel = max((errs[u] / max(abs(theta[idx[u]]), 1e-12)
                   for u in evidenced), default=math.inf)
        bw_evidence = counts[_BW] >= self.min_obs
        converged = (all(counts[d] >= self.min_obs for d in devs)
                     and bool(devs) and rel <= self.rel_tol)
        return HostEstimate(
            wid=wid,
            ratios={d: theta[idx[d]] for d in devs},
            bw_ratio=theta[k - 1], bw_evidence=bw_evidence,
            n=len(obs), rel_err=rel, converged=converged)

    # -- publish gate ----------------------------------------------------------
    def publishable(self, wid: str) -> HostProfile | None:
        """The learned ``HostProfile`` ready to publish for ``wid``, or
        None: requires a converged estimate that deviates from the current
        belief beyond ``dead_band`` (so a healthy fleet never publishes,
        and a just-published profile goes quiet until genuine new drift).
        The returned profile is the estimate composed over the belief —
        absolute truth physics, directly comparable to a declared
        profile."""
        est = self.estimate(wid)
        if est is None or not est.converged:
            return None
        off = any(abs(r - 1.0) > self.dead_band
                  for r in est.ratios.values())
        if est.bw_evidence and abs(est.bw_ratio - 1.0) > self.dead_band:
            off = True
        if not off:
            return None
        belief = self.beliefs.get(wid, UNIFORM_HOST)
        scales = {d: r * belief.device_scale(d)
                  for d, r in est.ratios.items()}
        # unobserved device types keep the belief's behavior
        for d, _ in belief.device_scales:
            scales.setdefault(d, belief.device_scale(d))
        bw = (belief.bw_scale / est.bw_ratio if est.bw_evidence
              else belief.bw_scale)
        vals = list(scales.values())
        # statistically indistinguishable per-device ratios collapse to a
        # uniform compute scale (the common uniformly-slow-host case)
        if vals and max(vals) - min(vals) <= 1e-3 * max(vals):
            return HostProfile(
                f"{wid}-learned", sum(vals) / len(vals), bw, ())
        cs = belief.compute_scale        # fallback for never-seen devices
        return HostProfile(
            f"{wid}-learned", cs, bw,
            tuple(sorted((d, v / cs) for d, v in scales.items())))

    def poll(self) -> list[tuple[str, HostProfile]]:
        """Every worker with a publishable profile right now (sorted by
        id, so publication order is deterministic)."""
        out = []
        for wid in sorted(self._obs):
            prof = self.publishable(wid)
            if prof is not None:
                out.append((wid, prof))
        return out

    def note_published(self, wid: str, profile: HostProfile) -> None:
        """The profile went live: it becomes the belief (the controller
        re-bakes schedules under it), and the evidence window resets —
        post-publication reports are expected back at ratio 1.0."""
        self.beliefs[wid] = profile
        self.published[wid] = profile
        self._obs.pop(wid, None)
        self._cache.pop(wid, None)
        self._dirty.discard(wid)

    # -- wiring ----------------------------------------------------------------
    def attach(self, router, controller):
        """Close the loop on a serving Router + cluster Controller: seed
        beliefs from the controller's registered (declared) profiles,
        register as the Router's estimator (report feed + straggler
        gating), and append a clock hook that pushes converged profiles
        through ``Controller.set_learned_profile`` — from where they flow
        into placement, DP re-solves, and steal decisions exactly like
        declared profiles."""
        for wid, link in controller.links.items():
            self.beliefs.setdefault(wid, link.profile)
        router.estimator = self

        def publish_tick(now: float):
            for wid, prof in self.poll():
                controller.set_learned_profile(wid, prof, now)
                self.note_published(wid, prof)
        router.clock_hooks.append(publish_tick)
        return self
