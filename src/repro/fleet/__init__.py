"""repro.fleet — data-aware fleet management for the cluster layer.

Closes the loop the paper's dynamic scheduler opens: the serving stack
already *measures* everything (per-stage times in every
``CompletionReport``), so host heterogeneity can be **learned** instead
of declared (``OnlineHostEstimator``), the offered-rate curve can be
**forecast** instead of chased (``ArrivalForecaster``), and capacity and
mode can move **ahead** of the diurnal peak (``PredictiveAutoscaler``).
All decisions are deterministic functions of the arrival/report streams
and are emitted as *derived* cluster events — recorded runs replay
byte-identically.
"""
from .autoscaler import PredictiveAutoscaler
from .estimator import HostEstimate, OnlineHostEstimator
from .forecast import ArrivalForecaster

__all__ = [
    "ArrivalForecaster",
    "HostEstimate",
    "OnlineHostEstimator",
    "PredictiveAutoscaler",
]
