"""PredictiveAutoscaler: act on the forecast instead of the damage.

Three actions, all taken *ahead* of load off the ``ArrivalForecaster``
and all emitted as derived cluster events (replay re-derives them):

  * **cell pre-warming** — when forecast utilization approaches the
    serving watermark, the hottest signature cells are admitted to the
    Engine before their next batch arrives, so the peak's first requests
    skip the DP-solve + deploy latency instead of paying it at the worst
    moment.
  * **elastic worker scaling** — through the existing join/leave listener
    path: a worker is *parked* (its device pool leaves the DP, placement
    and steal skip it; the peer stays alive and heartbeating) when the
    forecast says the fleet is oversized, and unparked the moment the
    forecast crosses back up — capacity returns before the peak, not
    after the queue has built.
  * **mode pre-flip** — not here: wiring the forecaster into
    ``LoadWatermarkPolicy`` makes the perf/energy watermark comparison
    itself look-ahead (the policy flips ~horizon seconds earlier); the
    autoscaler only handles the actions the policy can't take.

Hysteresis: scaling actions respect a ``cooldown`` (a forecast
oscillating around a threshold cannot park/unpark every tick), parking
requires a *dry* worker (nothing in flight), and ``min_active`` workers
always stay unparked. Single-threaded, driven as a Router clock hook.
"""
from __future__ import annotations

from .forecast import ArrivalForecaster


class PredictiveAutoscaler:
    def __init__(self, forecaster: ArrivalForecaster, *,
                 prewarm: int = 1, up: float = 0.7, down: float = 0.25,
                 cooldown: float = 10.0, min_active: int = 1,
                 interval: float = 1.0):
        assert down < up
        self.forecaster = forecaster
        self.prewarm = prewarm         # hot signatures to keep resident
        self.up = up                   # forecast util to scale up at
        self.down = down               # forecast util to scale down at
        self.cooldown = cooldown       # min seconds between scale actions
        self.min_active = min_active
        self.interval = interval       # decision cadence (sim seconds)
        self.router = None
        self.controller = None
        self.actions: list[tuple] = []     # (t, action, wid/sig)
        self.last_util = 0.0
        self._last_tick = -float("inf")
        self._last_scale = -float("inf")

    def attach(self, router, controller):
        """Wire into a serving Router + cluster Controller as a clock
        hook (the same cadence the controller ticks on)."""
        self.router = router
        self.controller = controller
        router.clock_hooks.append(self.tick)
        return self

    # -- the decision tick -----------------------------------------------------
    def tick(self, now: float):
        if now - self._last_tick < self.interval - 1e-9:
            return None
        self._last_tick = now
        cap = self.router.capacity()
        if cap <= 0 or not self.forecaster.warmed_up:
            return None
        util = self.forecaster.forecast(now) / cap
        self.last_util = util
        if self.prewarm and util >= self.up:
            self._prewarm_hot(now)
        if now - self._last_scale >= self.cooldown - 1e-9:
            if util >= self.up:
                self._unpark_one(now, util)
            elif util <= self.down:
                self._park_one(now, util)
        return None

    def _prewarm_hot(self, now: float) -> None:
        warmed = False
        for sig, wl in self.forecaster.hot_signatures(self.prewarm):
            if self.router.prewarm(wl, now):
                warmed = True
                self.actions.append((now, "prewarm", sig))
                ctrl = self.controller
                if ctrl is not None:
                    from ..cluster.events import ClusterEvent
                    ctrl.events.append(ClusterEvent(
                        now, "autoscale", "",
                        {"action": "prewarm", "sig": str(sig)}))
        if warmed and self.controller is not None:
            # pre-warming targets *replicas* too: a freshly admitted hot
            # cell fans out to its replica set now, ahead of the peak,
            # instead of waiting for the controller's next tick
            hook = getattr(self.controller, "replicate_hot_cells", None)
            if hook is not None:
                hook(now)

    def _unpark_one(self, now: float, util: float) -> None:
        parked = sorted(l.wid for l in self.controller.links.values()
                        if l.alive and l.parked)
        if parked and self.controller.set_parked(
                parked[0], False, now, reason=f"util={util:.2f}"):
            self.actions.append((now, "unpark", parked[0]))
            self._last_scale = now

    def _park_one(self, now: float, util: float) -> None:
        active = [l for l in self.controller.links.values()
                  if l.alive and not l.parked]
        if len(active) <= self.min_active:
            return
        # only a dry worker parks (nothing in flight, busy clock passed);
        # highest id first, so the founding workers are the last to go
        cands = sorted((l.wid for l in active
                        if not l.sids and l.busy_est <= now + 1e-9),
                       reverse=True)
        if cands and self.controller.set_parked(
                cands[0], True, now, reason=f"util={util:.2f}"):
            self.actions.append((now, "park", cands[0]))
            self._last_scale = now
