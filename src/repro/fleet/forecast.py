"""ArrivalForecaster: short-horizon look-ahead on the offered-rate curve.

The ``LoadWatermarkPolicy`` is threshold-*reactive*: it flips perf/energy
mode only after the trailing-window rate has already crossed a watermark,
so every diurnal peak is served in the wrong mode for one detection lag
(and every flip costs a reschedule + redeploy mid-rush). The paper's §II
traffic-forecasting example is predictive; this module supplies the
forecast.

Mechanism: arrivals are bucketed on a fixed ``dt`` grid and smoothed
with Holt's double exponential smoothing — a level (EWMA of the bucket
rate) plus a trend (EWMA of the level's slope). The ``horizon``-ahead
forecast is ``level + trend * horizon``: on the rising edge of a diurnal
curve the trend is positive, so the forecast crosses the high watermark
roughly ``horizon`` seconds before the measured rate does — mode flips,
cell pre-warms, and worker unparks all happen *ahead* of the load. The
per-signature split (EWMA of each signature's bucket share, with a
sample workload kept per signature) is what lets the autoscaler pre-warm
the right cells, not just more cells.

Deterministic: state is a pure function of the observed arrival times
(bucket grid, not wall clock), so every decision taken on a forecast is
a *derived* event that replays identically. Single-threaded, driven by
the host control loop.
"""
from __future__ import annotations


class ArrivalForecaster:
    def __init__(self, *, horizon: float = 5.0, dt: float = 1.0,
                 alpha: float = 0.35, beta: float = 0.15,
                 warmup_buckets: int = 3):
        assert horizon >= 0.0 and dt > 0.0
        self.horizon = horizon
        self.dt = dt
        self.alpha = alpha                 # level smoothing
        self.beta = beta                   # trend smoothing
        self.warmup_buckets = warmup_buckets
        self.level: float | None = None    # requests/s
        self.trend = 0.0                   # requests/s per second
        self._t0 = 0.0                     # current bucket start
        self._n = 0                        # arrivals in current bucket
        self._buckets = 0                  # closed buckets so far
        # signature -> (rate EWMA over buckets, current-bucket count)
        self._sig_rate: dict = {}
        self._sig_n: dict = {}
        self._sig_wl: dict = {}            # signature -> sample workload

    # -- ingest ----------------------------------------------------------------
    def observe(self, t: float, wl=None, sig=None) -> None:
        """One arrival at simulated time ``t``; ``wl`` (plus its
        precomputed ``sig``nature, when the caller has one) feeds the
        per-signature heat ranking for cell pre-warming."""
        self._roll(t)
        self._n += 1
        if sig is None and wl is not None:
            from ..core.dynamic import signature
            sig = signature(wl)
        if sig is not None:
            self._sig_n[sig] = self._sig_n.get(sig, 0) + 1
            if wl is not None:
                self._sig_wl.setdefault(sig, wl)

    def _roll(self, now: float) -> None:
        """Close every bucket the clock has passed (empty ones included —
        silence is evidence of a falling rate, not missing data)."""
        while now >= self._t0 + self.dt:
            rate = self._n / self.dt
            if self.level is None:
                self.level = rate
            else:
                prev = self.level
                self.level = (self.alpha * rate
                              + (1 - self.alpha)
                              * (self.level + self.trend * self.dt))
                self.trend = (self.beta * (self.level - prev) / self.dt
                              + (1 - self.beta) * self.trend)
            for sig in set(self._sig_rate) | set(self._sig_n):
                r = self._sig_n.get(sig, 0) / self.dt
                old = self._sig_rate.get(sig, r)
                self._sig_rate[sig] = (self.alpha * r
                                       + (1 - self.alpha) * old)
            self._sig_n = {}
            self._n = 0
            self._t0 += self.dt
            self._buckets += 1

    # -- queries ---------------------------------------------------------------
    @property
    def warmed_up(self) -> bool:
        return self._buckets >= self.warmup_buckets

    def forecast(self, now: float, horizon: float | None = None) -> float:
        """Forecast offered rate (requests/s) at ``now + horizon``. Until
        the warmup buckets close it degrades to the current level (no
        trend extrapolation off a sliver of history)."""
        self._roll(now)
        if self.level is None:
            return 0.0
        if not self.warmed_up:
            return max(0.0, self.level)
        h = self.horizon if horizon is None else horizon
        return max(0.0, self.level + self.trend * h)

    def sig_rate(self, sig) -> float:
        """Smoothed arrival rate (requests/s) of one signature — the
        heat the governor ranks cells by (coldest downshift first)."""
        return self._sig_rate.get(sig, 0.0)

    def sig_forecast(self, now: float, sig,
                     horizon: float | None = None) -> float:
        """Per-signature forecast rate: the total ``forecast`` scaled by
        the signature's smoothed share of the offered load. The split is
        assumed stationary over the horizon (the trend lives in the
        total), which is exactly the assumption ``hot_signatures``'s
        ranking already makes."""
        total = self.forecast(now, horizon)
        if total <= 0.0:
            return 0.0
        rates = sum(self._sig_rate.values())
        if rates <= 0.0:
            return 0.0
        return total * self._sig_rate.get(sig, 0.0) / rates

    def signatures(self) -> list[tuple]:
        """Every (signature, sample workload) the stream has shown us,
        sorted by signature — the deterministic iteration order the
        ParetoGovernor walks when assigning operating points."""
        return [(sig, self._sig_wl[sig]) for sig in sorted(self._sig_wl)]

    def hot_signatures(self, k: int = 2) -> list[tuple]:
        """Top-``k`` (signature, sample workload) by smoothed arrival
        rate — the cells worth pre-warming ahead of a peak. Ties break on
        the signature itself, so the ranking is deterministic."""
        ranked = sorted(self._sig_rate.items(),
                        key=lambda it: (-it[1], it[0]))
        out = []
        for sig, _ in ranked[:k]:
            wl = self._sig_wl.get(sig)
            if wl is not None:
                out.append((sig, wl))
        return out
