"""jax version compatibility for the Pallas TPU kernels."""
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 ships this as TPUCompilerParams; newer releases renamed it
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover
    raise ImportError(
        "this jax exposes neither pallas.tpu.CompilerParams nor "
        "pallas.tpu.TPUCompilerParams")
