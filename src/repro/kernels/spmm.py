"""Blocked-ELL SpMM Pallas TPU kernel — the TPU-native re-design of the
paper's customized Sextans [30] FPGA SpMM.

Sextans streams CSR non-zeros through 640 MAC units with the dense matrix
resident in HBM. A TPU gets no value from scalar streaming — the MXU wants
128x128 dense tiles — so the adaptation (DESIGN.md §2) re-blocks the sparse
matrix into a *blocked-ELL* format: each (bm x bk) tile that contains any
non-zero is stored densely, padded to a fixed number of tiles per block-row
(the ELL width). The kernel then:

  * prefetches the column-block index array as a scalar operand, so the
    BlockSpec index_map of the dense operand gathers exactly the needed
    (bk x N) slab of X into VMEM per grid step (data-dependent tiling — the
    TPU analogue of Sextans' HBM channel streaming),
  * runs one (bm x bk) @ (bk x N) MXU matmul per step, accumulating the
    block-row's output tile in place.

Padding tiles point at column-block 0 with all-zero values, so they
contribute nothing (branch-free, like Sextans' zero-padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


# ---------------------------------------------------------------------------
# format conversion (host-side, numpy)
# ---------------------------------------------------------------------------
def to_blocked_ell(a_dense: np.ndarray, bm: int = 128, bk: int = 128):
    """Dense (M, K) -> (blocks (nbr, ell, bm, bk), idx (nbr, ell) int32).
    ell = max non-empty column-blocks over the block-rows."""
    M, K = a_dense.shape
    assert M % bm == 0 and K % bk == 0, (M, K, bm, bk)
    nbr, nbc = M // bm, K // bk
    tiles = a_dense.reshape(nbr, bm, nbc, bk).transpose(0, 2, 1, 3)
    nonzero = np.abs(tiles).sum(axis=(2, 3)) > 0          # (nbr, nbc)
    ell = max(int(nonzero.sum(axis=1).max()), 1)
    blocks = np.zeros((nbr, ell, bm, bk), a_dense.dtype)
    idx = np.zeros((nbr, ell), np.int32)
    for r in range(nbr):
        cols = np.nonzero(nonzero[r])[0]
        for e, c in enumerate(cols):
            blocks[r, e] = tiles[r, c]
            idx[r, e] = c
    return blocks, idx


def _spmm_kernel(idx_ref, a_ref, x_ref, o_ref):
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0, 0]                                   # (bm, bk)
    x = x_ref[...]                                    # (bk, N)
    o_ref[...] += jax.lax.dot(a.astype(jnp.float32),
                              x.astype(jnp.float32),
                              preferred_element_type=jnp.float32
                              ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmm_blocked_ell(blocks, idx, x, *, interpret: bool = True):
    """(nbr, ell, bm, bk) blocked-ELL  @  (K, N) -> (M, N)."""
    nbr, ell, bm, bk = blocks.shape
    K, N = x.shape
    grid = (nbr, ell)
    return pl.pallas_call(
        _spmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bm, bk), lambda r, e, idx: (r, e, 0, 0)),
                # data-dependent gather of the X slab this tile needs
                pl.BlockSpec((bk, N), lambda r, e, idx: (idx[r, e], 0)),
            ],
            out_specs=pl.BlockSpec((bm, N), lambda r, e, idx: (r, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((nbr * bm, N), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(idx, blocks, x)
