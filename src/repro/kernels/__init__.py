"""Pallas TPU kernels for the perf-critical compute hot-spots:

  * ``swa``  — banded flash-attention (TPU re-design of SWAT [6])
  * ``spmm`` — blocked-ELL SpMM (TPU re-design of customized Sextans [30])
  * ``ssd``  — Mamba2 SSD chunk scan (the mamba2/zamba2 hot spot)

Each kernel ships with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py / models.ssm.ssd_chunked); tests sweep shapes/dtypes and assert
allclose in interpret mode.
"""
from .swa import swa_attention_pallas
from .spmm import spmm_blocked_ell, to_blocked_ell
from .ssd import ssd_chunked_pallas
from .ops import swa_attention_op, spmm_op
from . import ref
