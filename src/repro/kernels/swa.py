"""Banded (sliding-window) flash-attention Pallas TPU kernel.

TPU-native adaptation of SWAT [6] (the paper's FPGA sliding-window attention
accelerator). SWAT streams the token sequence through a systolic pipeline
that only ever holds the current window; the TPU analogue is a *banded*
flash-attention: the kv-block grid dimension visits only the blocks inside
the window of each query block, so compute and memory are O(S * w) instead
of O(S^2), and the S matrix is never materialized (this fusion is the
beyond-paper optimization vs. the paper's separate SDDMM/softmax/SpMM
stages — see DESIGN.md §7).

Layout: q, k, v are (B, H, S, D) with K/V possibly having fewer (KV) heads
(GQA); the kernel maps query head h to kv head h // (H // KV) in the
BlockSpec index_map — no materialized broadcast.

Tiling: q is tiled (blk, D) and each grid step loads one (blk, D) kv tile
into VMEM; blk defaults to 128 so the MXU matmuls are 128-aligned. The
online-softmax state (m, l, acc) lives in VMEM scratch across the innermost
(kv) grid dimension.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                blk: int, window: int, nkv: int, scale: float):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    kb = iq + jk - (nkv - 1)          # kv block index this step visits

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kb >= 0)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (blk, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (blk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        row = iq * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
        col = kb * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
        rel = row - col
        valid = (rel >= 0) & (rel < window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # fully-masked rows (m_new == NEG_INF): keep them inert
        p = jnp.where(valid, p, 0.0)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        m_ref[...] = m_new

    @pl.when(jk == nkv - 1)
    def _fini():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "scale", "blk", "interpret"))
def swa_attention_pallas(q, k, v, *, window: int, scale: float,
                         blk: int = 128, interpret: bool = True):
    """Banded flash attention. q: (B, H, S, D); k, v: (B, KV, S, D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    assert S % blk == 0, (S, blk)
    assert window % blk == 0, (window, blk)
    nq = S // blk
    nkv = min(window // blk + 1, nq)

    grid = (B, H, nq, nkv)
    q_spec = pl.BlockSpec((1, 1, blk, D), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, blk, D),
        lambda b, h, i, j: (b, h // G, jnp.maximum(i + j - (nkv - 1), 0), 0))
    o_spec = pl.BlockSpec((1, 1, blk, D), lambda b, h, i, j: (b, h, i, 0))

    kernel = functools.partial(_swa_kernel, blk=blk, window=window,
                               nkv=nkv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        # (m, l, acc) online-softmax carry lives in VMEM scratch across the
        # innermost (kv) grid dimension
        scratch_shapes=[
            pltpu.VMEM((blk,), jnp.float32),
            pltpu.VMEM((blk,), jnp.float32),
            pltpu.VMEM((blk, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
