"""Mamba2 SSD (state-space duality) chunk-scan Pallas TPU kernel.

The SSD computation is the hot spot of the mamba2/zamba2 architectures: per
chunk it is two MXU matmuls (the attention-like intra-chunk term) plus a
rank-Q state update, with a sequential state carried across chunks. The
kernel maps that structure directly:

  grid = (batch, heads, n_chunks)  — n_chunks is the sequential ("arbitrary")
  dimension; the (P, N) state lives in VMEM scratch across it, exactly like
  the online-softmax carry of flash attention. Per step:

      W    = (C B^T) ⊙ M ⊙ dt          (Q,Q)  one MXU matmul + mask
      y    = W x + (C S^T) ⊙ e^la      (Q,P)  two MXU matmuls
      S'   = e^{la_Q} S + (x ⊙ w)^T B  (P,N)  one MXU matmul

Q (chunk) and N (state) default to 128/256-aligned so every matmul hits the
MXU; dt/decay streams are kept 2-D (Q, 1) for TPU layout friendliness.
The pure-jnp oracle is ``models/ssm.py:ssd_chunked`` (also the model path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, s_out_ref,
                s_ref, *, nc: int, Q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q, 1)
    da = da_ref[0, 0, 0].astype(jnp.float32)        # (Q, 1)
    B = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    C = c_ref[0, 0].astype(jnp.float32)             # (Q, N)

    la = jnp.cumsum(da, axis=0)                     # (Q, 1) log decay
    seg = la - la.T                                 # (Q, Q): la_s - la_t
    iq = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    it = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(iq >= it, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    W = cb * M * dt.T                               # ⊙ dt_t
    y = jax.lax.dot(W, x, preferred_element_type=jnp.float32)      # (Q,P)

    S = s_ref[...]                                  # (P, N)
    y = y + jax.lax.dot_general(C, S, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32
                                ) * jnp.exp(la)
    # state update: S' = e^{la_Q} S + (x ⊙ w)^T B, w = e^{la_Q - la} dt
    w = jnp.exp(la[-1:] - la) * dt                  # (Q, 1)
    s_ref[...] = (S * jnp.exp(la[-1]) +
                  jax.lax.dot_general(x * w, B, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32))
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fini():
        s_out_ref[0, 0] = s_ref[...].astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(x, dt, B, C, A_log, D, *, chunk: int = 128,
                       interpret: bool = True):
    """Drop-in for models.ssm.ssd_chunked (zero init state).

    x: (b,L,H,P); dt: (b,L,H) raw (softplus applied here); B/C: (b,L,N).
    Returns (y (b,L,H,P), final_state (b,H,P,N))."""
    b, L, H, Pd = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    a = -jnp.exp(A_log.astype(jnp.float32))                    # (H,)
    dts = jax.nn.softplus(dt.astype(jnp.float32))              # (b,L,H)
    da = dts * a                                               # (b,L,H)

    # chunked, head-major layouts
    xq = x.reshape(b, nc, Q, H, Pd).transpose(0, 3, 1, 2, 4)   # (b,H,nc,Q,P)
    dtq = dts.reshape(b, nc, Q, H).transpose(0, 3, 1, 2)[..., None]
    daq = da.reshape(b, nc, Q, H).transpose(0, 3, 1, 2)[..., None]
    Bq = B.reshape(b, nc, Q, N)
    Cq = C.reshape(b, nc, Q, N)

    grid = (b, H, nc)
    y, s_final = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, Pd), lambda i, h, c: (i, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1), lambda i, h, c: (i, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1), lambda i, h, c: (i, h, c, 0, 0)),
            # B/C shared across heads: index_map drops h
            pl.BlockSpec((1, 1, Q, N), lambda i, h, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, h, c: (i, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, Pd), lambda i, h, c: (i, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Pd, N), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, nc, Q, Pd), x.dtype),
            jax.ShapeDtypeStruct((b, H, Pd, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, dtq, daq, Bq, Cq)

    y = y.transpose(0, 2, 3, 1, 4).reshape(b, L, H, Pd)
    y = y + (D.astype(jnp.float32)[None, None, :, None]
             * x.astype(jnp.float32)).astype(y.dtype)
    return y, s_final
