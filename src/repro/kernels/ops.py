"""Public jit'd wrappers around the Pallas kernels.

These take the model-zoo layouts ((B, S, H, D) activations, dense (M, K)
sparse operands) and handle layout transposition + format conversion, so the
rest of the framework never touches BlockSpecs. ``interpret=True`` (the
default on CPU) runs the kernel bodies in Python for validation; on real TPU
pass interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .spmm import spmm_blocked_ell, to_blocked_ell
from .swa import swa_attention_pallas


@functools.partial(jax.jit,
                   static_argnames=("window", "scale", "blk", "interpret"))
def swa_attention_op(q, k, v, *, window: int, scale: float, blk: int = 128,
                     interpret: bool = True):
    """Sliding-window attention, model layout: q (B,S,H,D), k/v (B,S,KV,D)."""
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    ot = swa_attention_pallas(qt, kt, vt, window=window, scale=scale,
                              blk=blk, interpret=interpret)
    return jnp.transpose(ot, (0, 2, 1, 3))


def spmm_op(a_dense: np.ndarray, x, *, bm: int = 128, bk: int = 128,
            interpret: bool = True):
    """SpMM with host-side blocked-ELL conversion (one-time; the format is
    cached by callers for repeated multiplies, mirroring the paper's
    pre-loaded static graph data)."""
    blocks, idx = to_blocked_ell(np.asarray(a_dense), bm, bk)
    return spmm_blocked_ell(jnp.asarray(blocks), jnp.asarray(idx),
                            jnp.asarray(x), interpret=interpret)
