"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def swa_attention_ref(q, k, v, *, window: int, scale: float):
    """Banded causal attention, materialized. q: (B,H,S,D); k,v: (B,KV,S,D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    row = jnp.arange(S)[:, None]
    col = jnp.arange(S)[None, :]
    rel = row - col
    valid = (rel >= 0) & (rel < window)
    s = jnp.where(valid[None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(valid[None, None], p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(p.sum(-1), 1e-30)[..., None]
    return out.astype(q.dtype)


def spmm_ref(blocks, idx, x):
    """Blocked-ELL -> dense scatter, then matmul. Matches spmm_blocked_ell."""
    nbr, ell, bm, bk = blocks.shape
    K, N = x.shape
    nbc = K // bk
    dense = np.zeros((nbr, nbc, bm, bk), np.float64)
    blocks = np.asarray(blocks, np.float64)
    idx = np.asarray(idx)
    for r in range(nbr):
        for e in range(ell):
            dense[r, idx[r, e]] += blocks[r, e]
    a = dense.transpose(0, 2, 1, 3).reshape(nbr * bm, K)
    return a @ np.asarray(x, np.float64)
